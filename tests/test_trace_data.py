"""Trace generator statistics, reuse-distance correctness, data pipelines."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.trace import (TraceGenConfig, generate_trace,
                              reuse_distance_cdf, reuse_distances)
from repro.data.dlrm_data import DLRMDataConfig, query_batches
from repro.data.lm_data import LMDataConfig, batch_at


def brute_reuse_distance(keys):
    out = []
    last = {}
    for i, k in enumerate(keys):
        if k in last:
            out.append(len(set(keys[last[k] + 1 : i])))
        else:
            out.append(-1)
        last[k] = i
    return np.array(out)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=120))
def test_reuse_distance_matches_bruteforce(keys):
    keys = np.array(keys)
    np.testing.assert_array_equal(reuse_distances(keys),
                                  brute_reuse_distance(keys))


def test_trace_power_law(tiny_trace):
    gid = tiny_trace.global_id
    vals, counts = np.unique(gid, return_counts=True)
    counts = np.sort(counts)[::-1]
    top20 = counts[: max(1, len(counts) // 5)].sum()
    # Power-law-ish: top 20% of vectors take a large share of accesses.
    assert top20 / counts.sum() > 0.5


def test_trace_long_reuse_tail(tiny_trace):
    edges, frac = reuse_distance_cdf(tiny_trace.global_id[:20000], 13)
    # A noticeable tail beyond typical buffer size (scaled analogue of the
    # paper's "20% of accesses beyond 2^20").
    assert frac[10] > 0.05


def test_trace_determinism():
    cfg = TraceGenConfig(n_tables=4, rows_per_table=100, n_accesses=5000)
    a = generate_trace(cfg)
    b = generate_trace(cfg)
    np.testing.assert_array_equal(a.global_id, b.global_id)


def test_trace_bounds(tiny_trace):
    assert tiny_trace.row_id.min() >= 0
    assert (tiny_trace.row_id < tiny_trace.rows_per_table[0]).all()
    assert (tiny_trace.table_id < tiny_trace.n_tables).all()


def test_lm_data_deterministic_resumable():
    cfg = LMDataConfig(vocab=128, seq_len=16, global_batch=2)
    a = batch_at(cfg, 5)
    b = batch_at(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
    assert (a["labels"][:, -1] == -1).all()


def test_dlrm_data_shapes():
    cfg = DLRMDataConfig(n_tables=4, rows_per_table=64, multi_hot=3, batch=8)
    batches = list(query_batches(cfg, n_batches=3))
    assert len(batches) == 3
    b = batches[0]
    assert b["dense"].shape == (8, 13)
    assert b["sparse"].shape == (8, 4, 3)
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    assert b["sparse"].max() < 64
