"""Paper §VII-F: end-to-end DLRM inference on tiered memory (Figs. 16/17),
the linear performance model (Fig. 18) and strategy estimates (Fig. 19)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BenchContext
from repro.configs import get_config
from repro.core.cache_sim import make_cache, simulate
from repro.core.perf_model import fit_perf_model
from repro.launch.serve import serve_trace
from repro.models.dlrm import init_dlrm


def _serving_cfg(ctx):
    import dataclasses

    # CPU-sized DLRM but with enough unique vectors (65K) that the access
    # distribution keeps its production-like skew/reuse structure.
    cfg = dataclasses.replace(
        get_config("dlrm-recmg").reduced(),
        n_tables=16, rows_per_table=4096, multi_hot=4, emb_dim=16,
    )
    from repro.core.trace import TraceGenConfig, generate_trace

    n_acc = 80_000 if ctx.cfg.quick else 160_000
    tr = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=n_acc, seed=0, drift_every=10**9))
    return cfg, tr


def fig16_17_e2e(ctx: BenchContext):
    cfg, tr = _serving_cfg(ctx)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    cap = int(0.18 * tr.unique_count())

    from repro.core.belady import belady_labels
    from repro.core.caching_model import CachingModelConfig, train_caching_model
    from repro.core.features import make_windows
    from repro.core.prefetch_model import (PrefetchModelConfig,
                                           make_prefetch_data,
                                           train_prefetch_model)
    from repro.core.recmg import precompute_outputs

    labels, _, _ = belady_labels(tr.global_id, cap)
    mcfg = CachingModelConfig(n_tables=cfg.n_tables)
    cparams, _ = train_caching_model(make_windows(tr, labels=labels), mcfg,
                                     epochs=ctx.cfg.epochs,
                                     batch_size=ctx.cfg.batch_size,
                                     lr=ctx.cfg.lr)
    pcfg = PrefetchModelConfig(n_tables=cfg.n_tables)
    pparams, _ = train_prefetch_model(make_prefetch_data(tr, stride=10), pcfg,
                                      epochs=ctx.cfg.epochs,
                                      batch_size=ctx.cfg.batch_size,
                                      lr=ctx.cfg.lr)
    out_cm = precompute_outputs(tr, caching=(cparams, mcfg))
    out_full = precompute_outputs(tr, caching=(cparams, mcfg),
                                  prefetch=(pparams, pcfg))
    # Oracle keep-bits: the mechanism's ceiling in serving (what a fully
    # trained caching model converges to — the paper trains 12+ hours).
    import numpy as np

    from repro.core.recmg import RecMGOutputs

    starts = out_cm.chunk_starts
    oracle_bits = np.stack([labels[max(0, int(s) - 15): int(s)]
                            for s in starts]).astype(bool)
    out_oracle = RecMGOutputs(starts, oracle_bits, None)

    results = {}
    for policy, outputs in (("lru", None), ("cm", out_cm),
                            ("recmg", out_full),
                            ("recmg-oracle", out_oracle)):
        pol = "recmg" if policy.startswith(("cm", "recmg")) else "lru"
        res = serve_trace(cfg, params, tr, cap, pol, outputs,
                          batch_queries=32)
        results[policy] = res
        ctx.emit("fig16", f"{policy}_hit_rate", res["hit_rate"])
        ctx.emit("fig16", f"{policy}_fetch_ms",
                 round(res["modeled_fetch_ms_per_batch"], 3),
                 "modeled slow-tier on-demand per batch")
        ctx.emit("fig16", f"{policy}_e2e_ms", round(res["modeled_e2e_ms"], 3),
                 "compute + slow-tier model (paper §VII-F decomposition)")
        # Tail latency trajectory (measured per-batch wall time).
        ctx.emit_percentiles("fig16", policy, res)
        # Full per-policy counter space into the artifact (reconciled).
        ctx.emit_snapshot("fig16", policy, res["metrics"])
    lru_t = results["lru"]["modeled_e2e_ms"]
    for name in ("cm", "recmg", "recmg-oracle"):
        red = 1 - results[name]["modeled_e2e_ms"] / max(lru_t, 1e-9)
        ctx.emit("fig16", f"{name}_time_reduction", round(red, 4),
                 "paper: 31% avg / 43% max (production traces, 12h training)")
    # The ML policy's bookkeeping must not slow the serving hot path: the
    # measured p50 batch latency of recmg vs lru is the perf-gate metric
    # (scripts/check_bench_regression.py); the array-backed priority
    # engine brought it from ~4.5x to ~1.1x.
    ratio = (results["recmg"]["p50_batch_ms"]
             / max(results["lru"]["p50_batch_ms"], 1e-9))
    ctx.emit("fig16", "recmg_lru_p50_ratio", round(ratio, 3),
             "acceptance: <= 1.5x (was ~4.5x with the heap)")
    return cfg, tr, cap, results, out_full


def fig18_19_perf_model(ctx: BenchContext):
    """Fit latency = f(hit rate) from controlled runs; estimate strategies."""
    cfg, tr = _serving_cfg(ctx)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    keys = tr.global_id

    # Controlled hit rates via buffer sizes (the paper re-orders traces; a
    # capacity sweep spans the same hit-rate axis).
    hrs, lats = [], []
    for frac in (0.01, 0.03, 0.08, 0.15, 0.3, 0.6):
        cap = max(16, int(frac * tr.unique_count()))
        res = serve_trace(cfg, params, tr.slice(0, 40_000), cap, "lru", None,
                          batch_queries=16)
        hrs.append(res["hit_rate"])
        lats.append(res["modeled_e2e_ms"])
    model = fit_perf_model(hrs, lats)
    ctx.emit("fig18", "slope_ms_per_hitrate", round(model.slope, 3))
    ctx.emit("fig18", "intercept_ms", round(model.intercept, 3))
    ctx.emit("fig18", "rmse_ms", round(model.rmse, 4),
             f"rel={model.rmse / max(np.mean(lats), 1e-9):.3f} "
             "(paper: <=1.7%)")

    # Fig. 19: estimated latency per strategy from simulated hit rates.
    cap = max(16, int(0.15 * tr.unique_count()))
    sims = {}
    for name in ("lru_32w", "srrip", "drrip", "hawkeye", "mockingjay"):
        sims[name] = simulate(keys, make_cache(name, cap)).hit_rate
    from repro.core.prefetchers import make_prefetcher

    sims["bop+lru"] = simulate(keys, make_cache("lru_32w", cap),
                               make_prefetcher("bop")).hit_rate
    lru_est = float(model.predict(sims["lru_32w"]))
    for name, hr in sims.items():
        est = float(model.predict(hr))
        ctx.emit("fig19", f"{name}_est_ms", round(est, 3),
                 f"vs lru: {1 - est / max(lru_est, 1e-9):+.3f}")
    return model


def quantized_buffer_beyond_paper(ctx: BenchContext):
    """Beyond-paper: quantized fast tier (SDM's capacity/precision trade,
    [90] in the paper) at a FIXED byte budget — a cell per paper-target
    scenario served end-to-end through the harness twice, fp32 rows vs
    int8 rows + per-row scales in the *same* bytes (the quantized arm
    holds ~2.7x the rows at D=8).  Two gated rows:

    * ``quantized_hit_rate_gain_at_fixed_bytes`` — worst-case quantized/
      fp32 hit-rate ratio over the paper-target cells; a floor metric
      with an absolute floor of 1.0 (the acceptance bar: quantization
      must improve the hit rate on EVERY paper-target cell).
    * ``quantized_dequant_max_abs_err`` — per-row dequantization error in
      units of the acceptance bound ``max|row|/127``; a ceiling metric
      with an absolute cap of 1.0 (round-half-even lands at ~0.5).
    """
    import numpy as np

    from repro.core.tiered import TieredEmbeddingStore, fast_row_bytes
    from repro.workloads import (PAPER_TARGET_SCENARIOS, replay_scenario,
                                 scenario)
    from repro.workloads.spec import make_trace

    n_acc = 16_384 if ctx.cfg.quick else 49_152
    scale = dict(n_tables=8, rows_per_table=2048, n_accesses=n_acc, seed=0)
    emb_dim = 8  # harness default; quantized row = 12 B vs 32 B fp32
    gains, cap_ratios = [], []
    for name in sorted(PAPER_TARGET_SCENARIOS):
        spec = scenario(name, **scale)
        # The budget a 12% fp32 buffer would spend — both arms get it.
        budget = (int(0.12 * make_trace(spec).unique_count())
                  * fast_row_bytes(emb_dim, np.float32, False))
        res_f = replay_scenario(spec, policy="lru", batch=512,
                                byte_budget=budget)
        res_q = replay_scenario(spec, policy="lru", batch=512,
                                byte_budget=budget, quantize=True)
        gains.append(res_q["hit_rate"] / max(res_f["hit_rate"], 1e-9))
        cap_ratios.append(res_q["capacity"] / max(res_f["capacity"], 1))
        ctx.emit("beyond", f"{name}_fp32_hit_rate_at_fixed_bytes",
                 round(res_f["hit_rate"], 4),
                 f"{res_f['capacity']} rows in {budget} B, "
                 f"p50 {res_f['p50_batch_ms']:.2f}ms")
        ctx.emit("beyond", f"{name}_int8_hit_rate_at_fixed_bytes",
                 round(res_q["hit_rate"], 4),
                 f"{res_q['capacity']} rows (same bytes), "
                 f"p50 {res_q['p50_batch_ms']:.2f}ms")
    ctx.emit("beyond", "quantized_capacity_ratio_at_fixed_bytes",
             round(min(cap_ratios), 3),
             "acceptance: >= 2x resident rows at the same byte budget")
    ctx.emit("beyond", "quantized_hit_rate_gain_at_fixed_bytes",
             round(min(gains), 4),
             f"worst over {sorted(PAPER_TARGET_SCENARIOS)}; perf-gate "
             "floor (abs floor 1.0: must improve on every cell)")
    # Numerical fidelity of the quantized tier, normalized per row by the
    # acceptance bound max|row|/127 (so the gate is scale-free).
    host = np.random.default_rng(0).normal(
        size=(1000, emb_dim)).astype(np.float32)
    st = TieredEmbeddingStore(host, 64, quantize=True)
    ids = np.arange(64)
    out = np.asarray(st.lookup(ids))
    amax = np.abs(host[ids]).max(axis=1)
    err = np.abs(out - host[ids]).max(axis=1)
    norm = float((err / (amax / 127.0 + 1e-9)).max())
    ctx.emit("beyond", "quantized_dequant_max_abs_err", round(norm, 4),
             "max per-row |dequant - host| / (max|row|/127); perf-gate "
             "ceiling (abs cap 1.0)")


def lookup_throughput(ctx: BenchContext):
    """Tentpole microbench: batched array-backed store vs. the per-key seed
    reference (kept in ``repro.core.tiered_reference``) on identical
    Zipf-skewed batches, LRU policy.  Acceptance bar: >= 3x at batch >=
    1024."""
    import time

    import numpy as np

    from repro.core.tiered import TieredEmbeddingStore
    from repro.core.tiered_reference import ReferenceTieredStore

    rng = np.random.default_rng(0)
    n_rows, d, batch = 65_536, 64, 2048
    host = rng.normal(size=(n_rows, d)).astype(np.float32)
    cap = n_rows // 8
    ranks = np.minimum(rng.zipf(1.1, size=64 * batch), n_rows) - 1
    ids = rng.permutation(n_rows)[ranks].astype(np.int64)
    n_batches = 16 if ctx.cfg.quick else 32

    def run_store(store, n_b):
        for b in range(30):  # warm the buffer + compile caches
            store.lookup(ids[b * batch: (b + 1) * batch])
        t0 = time.perf_counter()
        for b in range(n_b):
            lo = (b % 30) * batch
            store.lookup(ids[lo: lo + batch])
        return n_b * batch / (time.perf_counter() - t0)

    fast = run_store(TieredEmbeddingStore(host, cap, policy="lru",
                                          warmup_batch=batch),
                     n_batches)
    slow = run_store(ReferenceTieredStore(host, cap, policy="lru"),
                     max(4, n_batches // 8))
    ctx.emit("tentpole", "batched_lookup_rows_per_s", round(fast),
             f"batch={batch} cap={cap} lru")
    ctx.emit("tentpole", "reference_lookup_rows_per_s", round(slow),
             "per-key seed implementation")
    ctx.emit("tentpole", "lookup_speedup_vs_reference",
             round(fast / max(slow, 1e-9), 2), "acceptance bar: >= 3x")
    return fast / max(slow, 1e-9)


def tracing_overhead(ctx: BenchContext):
    """Observability cost rows: the batched-lookup microbench with the
    default ``NullTracer`` (tracing off — the mode every perf gate runs
    in, so the throughput/latency gates themselves enforce near-zero
    disabled cost) and again with a ``SpanTracer`` installed.  The
    tracing-on slowdown is itself a gated ceiling row
    (``tracing_on_lookup_slowdown``): span emission must stay a few
    percent of the lookup hot path, not a profiling mode you can't
    afford in production."""
    import time

    import numpy as np

    from repro.core.tiered import TieredEmbeddingStore
    from repro.obs.tracing import SpanTracer, install_tracer

    rng = np.random.default_rng(1)
    n_rows, d, batch = 65_536, 64, 2048
    host = rng.normal(size=(n_rows, d)).astype(np.float32)
    cap = n_rows // 8
    ranks = np.minimum(rng.zipf(1.1, size=64 * batch), n_rows) - 1
    ids = rng.permutation(n_rows)[ranks].astype(np.int64)
    n_batches = 16 if ctx.cfg.quick else 32

    def run(n_b):
        store = TieredEmbeddingStore(host, cap, policy="lru",
                                     warmup_batch=batch)
        for b in range(30):
            store.lookup(ids[b * batch: (b + 1) * batch])
        t0 = time.perf_counter()
        for b in range(n_b):
            lo = (b % 30) * batch
            store.lookup(ids[lo: lo + batch])
        return n_b * batch / (time.perf_counter() - t0)

    off = run(n_batches)
    tracer = SpanTracer(ring_batches=8)
    install_tracer(tracer)
    try:
        on = run(n_batches)
    finally:
        install_tracer(None)
    ctx.emit("obs", "tracing_off_rows_per_s", round(off),
             "NullTracer (default): the gated perf numbers run like this")
    ctx.emit("obs", "tracing_on_rows_per_s", round(on),
             f"SpanTracer installed ({len(tracer.events)} events)")
    ctx.emit("obs", "tracing_on_lookup_slowdown",
             round(off / max(on, 1e-9), 3),
             "perf-gate ceiling: span emission stays off the hot path")


def multi_table_facade(ctx: BenchContext):
    """Per-table facade vs. monolithic store at the same total row budget
    (per-table isolation: a hot table cannot starve the rest)."""
    cfg, tr = _serving_cfg(ctx)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    cap = int(0.18 * tr.unique_count())
    short = tr.slice(0, 40_000)
    mono = serve_trace(cfg, params, short, cap, "lru", None,
                       batch_queries=32)
    multi = serve_trace(cfg, params, short, cap, "lru", None,
                        batch_queries=32, multi_table=True)
    ctx.emit("facade", "mono_hit_rate", mono["hit_rate"])
    ctx.emit("facade", "multi_table_hit_rate", multi["hit_rate"],
             f"{cfg.n_tables} per-table stores, shared {cap}-row budget")
    ctx.emit("facade", "multi_table_fetch_ms",
             round(multi["modeled_fetch_ms_per_batch"], 3),
             f"mono: {mono['modeled_fetch_ms_per_batch']:.3f}")


def runtime_pipeline(ctx: BenchContext, cfg, tr, cap, outputs, sync_res):
    """Pipelined serving runtime vs. the synchronous path (same trace,
    capacity and predictions): the pipelined run must reproduce the
    synchronous hit/miss/eviction counters exactly while moving on-demand
    fetch time off the modeled critical path (acceptance: >= 30% lower
    stall on the recmg policy)."""
    import jax

    from repro.models.dlrm import init_dlrm

    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    # One cost model for both pipeline stages.  The modeled device time
    # per batch is the synchronous run's own mean per-batch compute,
    # floored at the modeled per-batch slow-tier fetch: this container's
    # CPU MLP runs in ~1ms (now that serve_trace warms the forward's XLA
    # compile out of the measured batches) while the modeled fetch is
    # ~12ms — mixing measured microsecond CPU compute with the modeled
    # 10us/row slow tier would understate what an accelerator-rate
    # forward can hide (the paper's Fig. 6 regime: fetch overlapped under
    # a forward of comparable length).
    compute_ms = max(sync_res["compute_ms"],
                     sync_res["modeled_fetch_ms_per_batch"])
    pipe = serve_trace(cfg, params, tr, cap, "recmg", outputs,
                       batch_queries=32, async_prefetch=True,
                       pipeline_depth=2,
                       compute_us=compute_ms * 1e3)
    equal = all(pipe[k] == sync_res[k] for k in
                ("hit_rate", "prefetch_hits", "on_demand_rows", "lookups",
                 "evictions", "batches"))
    rt = pipe["runtime"]
    sync_stall = sync_res["on_demand_stall_ms"]
    red = 1 - pipe["on_demand_stall_ms"] / max(sync_stall, 1e-9)
    ctx.emit("runtime", "counters_equal_sync_vs_pipelined", equal,
             "determinism contract: identical hit/miss/eviction counters")
    ctx.emit("runtime", "sync_fetch_stall_ms", round(sync_stall, 3),
             "synchronous path: every on-demand fetch on the critical path")
    ctx.emit("runtime", "pipelined_fetch_stall_ms",
             round(pipe["on_demand_stall_ms"], 3),
             "after overlapping batch k's fetch with batch k-1's forward")
    ctx.emit("runtime", "stall_reduction", round(red, 4),
             "acceptance bar: >= 0.30 (recmg policy, depth 2)")
    ctx.emit("runtime", "hidden_ms", rt["hidden_ms"],
             "fetch time overlapped with compute")
    ctx.emit("runtime", "pf_timeliness", rt["pf_timeliness"],
             f"timely {rt['pf_timely']} / late {rt['pf_late']} "
             f"(modeled background channel)")
    ctx.emit("runtime", "pf_issued_rows", rt["pf_issued"],
             f"deduped {rt['pf_deduped']}, "
             f"cancelled resident {rt['pf_cancelled_resident']}")
    for q in ("req_p50_ms", "req_p95_ms", "req_p99_ms"):
        ctx.emit("runtime", q, rt[q],
                 "modeled per-request latency (admission -> completion)")
    ctx.emit_percentiles("runtime", "pipelined", pipe)
    ctx.emit_snapshot("runtime", "pipelined", pipe["metrics"],
                      "store + rt counter space of the pipelined run")
    return red


def sharded_placements(ctx: BenchContext, n_shards: int = 4):
    """Sharded multi-worker serving, one row set per placement policy:
    hit rate, tail latency, max-shard load imbalance, and the parallel
    critical-path fetch (workers fetch concurrently, the batch pays the
    slowest shard).  The RecShard-style ``freq`` planner should match or
    beat the monolithic hit rate; ``row``/``hash`` should pin imbalance
    near 1.0."""
    from repro.sharding.embedding_shard import PLACEMENTS

    cfg, tr = _serving_cfg(ctx)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    cap = int(0.18 * tr.unique_count())
    short = tr.slice(0, 40_000)
    mono = serve_trace(cfg, params, short, cap, "lru", None,
                       batch_queries=32)
    ctx.emit("sharded", "mono_hit_rate", mono["hit_rate"],
             f"single worker, {cap}-row budget")
    for placement in PLACEMENTS:
        res = serve_trace(cfg, params, short, cap, "lru", None,
                          batch_queries=32, shards=n_shards,
                          placement=placement)
        sh = res["shard"]
        ctx.emit("sharded", f"{placement}_hit_rate", res["hit_rate"],
                 f"{n_shards} workers")
        ctx.emit("sharded", f"{placement}_load_imbalance",
                 sh["load_imbalance"],
                 f"max/mean shard load (worst batch "
                 f"{sh['max_batch_imbalance']})")
        ctx.emit("sharded", f"{placement}_fetch_ms_critical",
                 round(sh["modeled_fetch_ms_critical"]
                       / max(res["batches"], 1), 3),
                 f"slowest-shard path; sum view "
                 f"{res['modeled_fetch_ms_per_batch']:.3f}, parallel "
                 f"speedup {sh['parallel_fetch_speedup']}")
        ctx.emit_percentiles("sharded", placement, res)


def scenario_matrix(ctx: BenchContext):
    """Beyond-paper workload-scenario matrix: every catalog scenario x
    {lru, recmg} through the model-free scenario harness (identical
    serving semantics, no dense forward) — per-scenario on-demand fetch
    count, hit rate and p50/p95 batch latency, plus two gate rows:

    * ``recmg_lru_on_demand_ratio_worst`` — worst-case ratio of recmg's
      on-demand fetches to LRU's over the paper-target regimes (ceiling
      metric: the ML policy must keep fetching less than LRU);
    * ``adapt_recovery`` — post-switch steady-state hit rate of
      drift-adaptive recmg on the diurnal regime relative to its
      pre-switch steady state (floor metric: the ISSUE's acceptance bar
      is 0.9 at the pinned test scale).
    """
    from repro.runtime.drift import DriftConfig
    from repro.workloads import (PAPER_TARGET_SCENARIOS, SCENARIOS,
                                 phase_steady_hit_rates, replay_scenario,
                                 scenario)

    n_acc = 16_384 if ctx.cfg.quick else 49_152
    scale = dict(n_tables=8, rows_per_table=2048, n_accesses=n_acc, seed=0)
    ratios = {}
    for name in sorted(SCENARIOS):
        per_policy = {}
        for policy in ("lru", "recmg"):
            res = replay_scenario(scenario(name, **scale), policy=policy,
                                  capacity_frac=0.12, batch=512)
            per_policy[policy] = res
            ctx.emit("scenario", f"{name}_{policy}_on_demand",
                     res["on_demand_rows"],
                     f"hit rate {res['hit_rate']}")
            ctx.emit("scenario", f"{name}_{policy}_p50_batch_ms",
                     round(res["p50_batch_ms"], 3))
            ctx.emit("scenario", f"{name}_{policy}_p95_batch_ms",
                     round(res["p95_batch_ms"], 3))
        r = (per_policy["recmg"]["on_demand_rows"]
             / max(per_policy["lru"]["on_demand_rows"], 1))
        ratios[name] = r
        ctx.emit("scenario", f"{name}_recmg_lru_on_demand_ratio",
                 round(r, 4), "paper direction: < 1 on target regimes")
    worst = max(ratios[n] for n in PAPER_TARGET_SCENARIOS)
    ctx.emit("scenario", "recmg_lru_on_demand_ratio_worst", round(worst, 4),
             f"over {sorted(PAPER_TARGET_SCENARIOS)}; perf-gate ceiling")

    # Drift-adaptation recovery row (diurnal, model frozen on phase 1).
    spec = scenario("diurnal", n_tables=4, rows_per_table=512,
                    n_accesses=16_384, seed=0)
    kw = dict(policy="recmg", batch=256, profile_frac=0.25,
              capacity_frac=0.12)
    frozen = replay_scenario(spec, **kw)
    adapt = replay_scenario(spec, adapt=True,
                            adapt_cfg=DriftConfig(window=1024, hot_k=128),
                            **kw)

    n_phases = int(spec.param("n_phases"))
    ph = phase_steady_hit_rates(adapt, n_phases)
    pre, post = ph[0], ph[1:].mean()
    ctx.emit("scenario", "adapt_recovery", round(post / max(pre, 1e-9), 4),
             f"post-switch steady hit {post:.3f} vs pre {pre:.3f}; "
             "perf-gate floor")
    ctx.emit("scenario", "frozen_decay",
             round(phase_steady_hit_rates(frozen, n_phases)[1:].mean()
                   / max(pre, 1e-9), 4),
             "same model without adaptation (the gap --adapt closes)")
    ctx.emit("scenario", "adapt_triggers", adapt["drift"]["triggers"],
             f"min jaccard {adapt['drift']['min_jaccard']}")
    ctx.emit_snapshot("scenario", "adapt_diurnal", adapt["metrics"],
                      "store + drift counter space of the adaptive run")


def learned_vs_voyager(ctx: BenchContext):
    """Learned dual-model RecMG vs the Voyager-class prefetch-only
    baseline (paper §VII-C: RecMG needs ~1/1.5 the on-demand fetches of
    Voyager because the caching model protects rows the prefetcher would
    have to re-fetch).  Both arms train on the same trace through the
    scenario harness; the gate row is the *worst* learned/voyager
    on-demand ratio over the covered scenarios — a ceiling metric with an
    absolute cap of 1.0 (learned must beat Voyager outright, not just
    stay near a baseline).

    Training cost dominates this bench, so the quick lane covers one
    paper-target scenario and the full lane all four.  The learned arm
    uses the :class:`LearnedModelConfig` defaults (tuned for exactly this
    scale) rather than ``ctx.cfg.epochs`` — a 1-epoch smoke model would
    undertrain and gate on noise.
    """
    from repro.workloads import PAPER_TARGET_SCENARIOS, replay_scenario, scenario

    names = (("zipf_mid",) if ctx.cfg.quick
             else tuple(sorted(PAPER_TARGET_SCENARIOS)))
    scale = dict(n_tables=4, rows_per_table=512, n_accesses=8192, seed=0)
    ratios = {}
    for name in names:
        spec = scenario(name, **scale)
        per_model = {}
        for model in ("learned", "voyager"):
            res = replay_scenario(spec, policy="recmg", model=model,
                                  capacity_frac=0.12, batch=256)
            per_model[model] = res
            ctx.emit("learned", f"{name}_{model}_on_demand",
                     res["on_demand_rows"], f"hit rate {res['hit_rate']}")
        r = (per_model["learned"]["on_demand_rows"]
             / max(per_model["voyager"]["on_demand_rows"], 1))
        ratios[name] = r
        ctx.emit("learned", f"{name}_learned_voyager_ratio", round(r, 4),
                 "paper target: ~1/1.5")
    worst = max(ratios.values())
    ctx.emit("learned", "recmg_vs_voyager_on_demand_ratio", round(worst, 4),
             f"worst over {list(names)}; perf-gate ceiling, hard cap 1.0")


def overload_degradation(ctx: BenchContext):
    """ROADMAP item 4: goodput under sustained overload.  Sweeps offered
    load 0.5x -> 4x of modeled compute capacity through the SLO-aware
    admission path on the VirtualClock (deterministic) and emits the
    smooth-degradation figure of merit the perf gate floors: goodput at
    4x must stay >= 0.7x of goodput at 1x — shedding and degraded
    answers absorb the excess instead of collapsing the service."""
    from repro.workloads import make_spec
    from repro.workloads.overload import degradation_ratio, overload_sweep

    n_acc = 24_000 if ctx.cfg.quick else 48_000
    spec = make_spec("sustained_overload", n_accesses=n_acc, seed=0)
    sweep = overload_sweep(loads=(0.5, 1.0, 2.0, 4.0), spec=spec,
                           policy="lru", batch=32, per_query=8)
    for x, r in sweep.items():
        tag = f"{x:g}x"
        ctx.emit("overload", f"goodput_rps_{tag}", r["goodput_rps"],
                 f"served {r['served']} shed {r['shed']} "
                 f"degraded {r['degraded']} of {r['admitted']}")
        ctx.emit("overload", f"p999_ms_{tag}", r["p999_ms"],
                 f"p99 {r['p99_ms']} ms; queue bound {r['queue_bound']}")
    r4 = sweep[4.0]
    ctx.emit("overload", "shed_4x", r4["shed"],
             f"lowest-priority-first: gold {r4['gold_shed']} "
             f"silver {r4['silver_shed']} bronze {r4['bronze_shed']}")
    ctx.emit("overload", "degraded_4x", r4["degraded"],
             f"stale rows {r4['degraded_rows_stale']} default rows "
             f"{r4['degraded_rows_default']}; pf suppressed "
             f"{r4['pf_suppressed']}")
    ratio = degradation_ratio(sweep)
    ctx.emit("overload", "overload_goodput_4x_vs_1x", round(ratio, 4),
             "smooth-degradation gate: absolute floor 0.7 (no collapse)")


def failover_resilience(ctx: BenchContext):
    """Goodput under a deterministic mid-run shard kill vs the same
    workload with no faults.  Hot-row replication + the degraded
    ``lookup_resident`` contract keep every answer exact-or-zero (the
    lockstep audit proves zero wrong rows) while recovery streams the
    lost resident set back as int8 chunks; the perf gate floors the
    kill/clean goodput ratio at 0.8 — losing a shard costs availability
    headroom, never correctness or a collapse."""
    from repro.workloads import make_spec
    from repro.workloads.chaos import (DEFAULT_FAULT_PLAN, chaos_sweep,
                                       failover_goodput)

    n_acc = 24_000 if ctx.cfg.quick else 48_000
    spec = make_spec("shard_failure", n_accesses=n_acc, seed=0)
    sweep = chaos_sweep(plans=(None, DEFAULT_FAULT_PLAN), spec=spec,
                        batch=128, shards=4, policy="lru")
    clean, kill = sweep[""], sweep[DEFAULT_FAULT_PLAN]
    ctx.emit("failover", "goodput_rps_clean", clean["goodput_rps"],
             f"{clean['batches']} batches, {clean['shards']} shards")
    ctx.emit("failover", "goodput_rps_kill", kill["goodput_rps"],
             f"plan {kill['fault_plan']}; replica rows "
             f"{kill['failover_replica']} degraded "
             f"{kill['failover_degraded']} of {kill['served']}")
    ctx.emit("failover", "wrong_rows_kill", kill["wrong_rows"],
             "lockstep byte-audit vs the no-fault run; contract: 0")
    ctx.emit("failover", "recovery_bytes_int8", kill["recovery_bytes"],
             f"{kill['recovery_rows']} rows in {kill['recovery_chunks']} "
             f"chunks; fp32-equivalent {kill['recovery_bytes_raw']} B")
    ratio = failover_goodput(sweep)
    ctx.emit("failover", "failover_goodput_kill_vs_clean", round(ratio, 4),
             "shard-loss resilience gate: absolute floor 0.8")


def run(ctx: BenchContext):
    lookup_throughput(ctx)
    tracing_overhead(ctx)
    cfg, tr, cap, results, out_full = fig16_17_e2e(ctx)
    runtime_pipeline(ctx, cfg, tr, cap, out_full, results["recmg"])
    fig18_19_perf_model(ctx)
    quantized_buffer_beyond_paper(ctx)
    multi_table_facade(ctx)
    sharded_placements(ctx)
    scenario_matrix(ctx)
    learned_vs_voyager(ctx)
    overload_degradation(ctx)
    failover_resilience(ctx)
