"""Paper Table I + Fig. 3 + Fig. 8 + Fig. 13 + Fig. 15: locality study and
caching-policy comparisons."""
from __future__ import annotations


from benchmarks.common import BenchContext, geomean
from repro.core.cache_sim import make_cache, simulate
from repro.core.recmg import run_recmg
from repro.core.trace import reuse_distance_cdf


def table1_overhead(ctx: BenchContext):
    """Embedding-access overhead vs caching ratio (modeled slow-tier time as
    a fraction of total batch time, per the paper's Table I structure)."""
    tr = ctx.trace(0)
    keys = tr.global_id
    compute_us_per_access = 0.5  # device compute per access (measured scale)
    for ratio in (1.0, 0.2, 0.07):
        cap = max(16, int(ratio * tr.unique_count()))
        res = simulate(keys[:50_000], make_cache("lru_fa", cap))
        fetch_us = res.on_demand * 10.0
        total_us = len(keys[:50_000]) * compute_us_per_access + fetch_us
        ctx.emit("table1", f"caching_ratio_{ratio:g}",
                 round(fetch_us / total_us, 4),
                 f"emb_access_overhead_frac(hit={res.hit_rate:.3f})")


def fig3_reuse_distance(ctx: BenchContext):
    tr = ctx.trace(0)
    edges, frac = reuse_distance_cdf(tr.global_id[:100_000], 17)
    for p in (8, 10, 12, 14, 16):
        ctx.emit("fig3", f"frac_rd_ge_2^{p}", round(float(frac[p]), 4),
                 "scaled analogue of paper's 20% >= 2^20")


def fig8_cache_hits(ctx: BenchContext):
    """Cache hits: LRU/LFU vs the caching model vs optgen, five datasets."""
    for ds in range(ctx.cfg.n_datasets):
        tr = ctx.trace(ds)
        keys = tr.global_id
        cap = ctx.capacity(ds)
        labels, opt_hits, _ = ctx.labels(ds)
        base = {}
        for name in ("lru_fa", "lru_32w", "lfu_32w"):
            base[name] = simulate(keys, make_cache(name, cap)).hits
        cparams, mcfg, acc = ctx.caching_model(ds)
        outputs = ctx.outputs(ds, use_prefetch=False)
        cm = run_recmg(tr, cap, outputs, use_prefetch=False)
        ctx.emit("fig8", f"ds{ds}_caching_model_acc", round(float(acc), 4),
                 "paper: ~83%")
        best_base = max(base.values())
        for name, h in base.items():
            ctx.emit("fig8", f"ds{ds}_{name}_hits", int(h))
        ctx.emit("fig8", f"ds{ds}_caching_model_hits", int(cm.hits),
                 f"vs best LRU/LFU: {cm.hits / max(best_base,1):.2f}x")
        ctx.emit("fig8", f"ds{ds}_optgen_hits", int(opt_hits.sum()),
                 f"OPT/LRU = {opt_hits.sum() / max(base['lru_fa'],1):.2f}x")


def fig13_buffer_size(ctx: BenchContext):
    """Hit rate vs buffer size: LRU, CM-only, RecMG, optgen."""
    ds = 0
    tr = ctx.trace(ds)
    keys = tr.global_id
    from repro.core.belady import belady_sim

    for frac in (0.01, 0.05, 0.10, 0.15, 0.30):
        cap = ctx.capacity(ds, frac)
        lru = simulate(keys, make_cache("lru_fa", cap))
        opt_hits, _ = belady_sim(keys, cap)
        outputs = ctx.outputs(ds, use_prefetch=True)
        cm = run_recmg(tr, cap, outputs, use_prefetch=False)
        full = run_recmg(tr, cap, outputs, use_prefetch=True)
        ctx.emit("fig13", f"cap{int(frac*100)}pct_lru",
                 round(lru.hit_rate, 4))
        ctx.emit("fig13", f"cap{int(frac*100)}pct_cm",
                 round(cm.hit_rate, 4))
        ctx.emit("fig13", f"cap{int(frac*100)}pct_recmg",
                 round(full.hit_rate, 4))
        ctx.emit("fig13", f"cap{int(frac*100)}pct_optgen",
                 round(float(opt_hits.mean()), 4))


def fig15_advanced_policies(ctx: BenchContext):
    """Advanced replacement (SRRIP/DRRIP/Hawkeye) + prefetchers (BOP) vs the
    caching model, geomean across 3 datasets and buffer sizes."""
    from repro.core.prefetchers import make_prefetcher

    sizes = (0.01, 0.05, 0.10, 0.15)
    n_ds = min(3, ctx.cfg.n_datasets)
    results = {}
    for frac in sizes:
        per_policy = {}
        for ds in range(n_ds):
            tr = ctx.trace(ds)
            keys = tr.global_id
            cap = ctx.capacity(ds, frac)
            for name in ("lru_32w", "srrip", "drrip", "hawkeye", "mockingjay"):
                per_policy.setdefault(name, []).append(
                    simulate(keys, make_cache(name, cap)).hit_rate)
            per_policy.setdefault("bop+lru", []).append(
                simulate(keys, make_cache("lru_32w", cap),
                         make_prefetcher("bop")).hit_rate)
            outputs = ctx.outputs(ds, use_prefetch=True)
            cm = run_recmg(tr, cap, outputs, use_prefetch=False)
            per_policy.setdefault("caching_model", []).append(cm.hit_rate)
            full = run_recmg(tr, cap, outputs, use_prefetch=True)
            per_policy.setdefault("recmg", []).append(full.hit_rate)
        for name, vals in per_policy.items():
            results.setdefault(name, []).append(geomean(vals))
            ctx.emit("fig15", f"cap{int(frac*100)}pct_{name}",
                     round(geomean(vals), 4))
    for name, vals in results.items():
        ctx.emit("fig15", f"geomean_{name}", round(geomean(vals), 4),
                 "across buffer sizes")


def run(ctx: BenchContext):
    table1_overhead(ctx)
    fig3_reuse_distance(ctx)
    fig8_cache_hits(ctx)
    fig13_buffer_size(ctx)
    fig15_advanced_policies(ctx)
