"""Shared benchmark context: datasets, Belady labels, trained RecMG models.

Scaled-down analogue of the paper's setup (five Meta production datasets,
856 tables, 62M vectors, 400M+ accesses) sized for this 1-core container:
five synthetic datasets (seeds 0-4) from the calibrated generator, 24
tables, configurable accesses.  Every resource is built lazily and cached
in-process so figures share work.  ``--quick`` shrinks traces/epochs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.belady import belady_labels
from repro.core.caching_model import (CachingModelConfig,
                                      evaluate_caching_model,
                                      train_caching_model)
from repro.core.features import make_windows, split_train_eval
from repro.core.prefetch_model import (
    PrefetchModelConfig, make_prefetch_data, train_prefetch_model)
from repro.core.trace import Trace, TraceGenConfig, generate_trace


@dataclass
class BenchConfig:
    n_datasets: int = 5
    n_tables: int = 24
    rows_per_table: int = 20_000
    n_accesses: int = 200_000
    cap_frac: float = 0.2
    epochs: int = 6
    batch_size: int = 512
    lr: float = 5e-3
    quick: bool = False

    def __post_init__(self):
        if self.quick:
            self.n_accesses = 60_000
            self.epochs = 2
            self.n_datasets = 3


class BenchContext:
    def __init__(self, cfg: Optional[BenchConfig] = None):
        self.cfg = cfg or BenchConfig()
        self._traces: Dict[int, Trace] = {}
        self._labels: Dict[Tuple[int, int], np.ndarray] = {}
        self._caching: Dict[int, tuple] = {}
        self._prefetch: Dict[int, tuple] = {}
        self._outputs: Dict[tuple, object] = {}
        self.rows: List[dict] = []

    # ---------------- resources ----------------
    def trace(self, ds: int) -> Trace:
        if ds not in self._traces:
            self._traces[ds] = generate_trace(TraceGenConfig(
                n_tables=self.cfg.n_tables,
                rows_per_table=self.cfg.rows_per_table,
                n_accesses=self.cfg.n_accesses,
                seed=ds, drift_every=10**9,
            ))
        return self._traces[ds]

    def capacity(self, ds: int, frac: Optional[float] = None) -> int:
        frac = frac if frac is not None else self.cfg.cap_frac
        return max(16, int(frac * self.trace(ds).unique_count()))

    def labels(self, ds: int, cap: Optional[int] = None):
        cap = cap or self.capacity(ds)
        key = (ds, cap)
        if key not in self._labels:
            self._labels[key] = belady_labels(self.trace(ds).global_id, cap)
        return self._labels[key]

    def caching_model(self, ds: int):
        """(params, cfg, eval_accuracy) trained on dataset ds."""
        if ds not in self._caching:
            tr = self.trace(ds)
            labels, _, _ = self.labels(ds)
            mcfg = CachingModelConfig(n_tables=tr.n_tables)
            data = make_windows(tr, labels=labels)
            trd, evd = split_train_eval(data)
            params, _ = train_caching_model(
                trd, mcfg, epochs=self.cfg.epochs,
                batch_size=self.cfg.batch_size, lr=self.cfg.lr,
            )
            acc = evaluate_caching_model(params, evd)
            self._caching[ds] = (params, mcfg, acc)
        return self._caching[ds]

    def prefetch_model(self, ds: int, loss: str = "chamfer",
                       window: int = 15, backbone: str = "lstm"):
        key = (ds, loss, window, backbone)
        if key not in self._prefetch:
            tr = self.trace(ds)
            pcfg = PrefetchModelConfig(n_tables=tr.n_tables, loss=loss,
                                       window=window, backbone=backbone)
            pdata = make_prefetch_data(tr, window=max(window, 15), stride=10)
            params, losses = train_prefetch_model(
                pdata, pcfg, epochs=self.cfg.epochs,
                batch_size=self.cfg.batch_size, lr=self.cfg.lr,
            )
            self._prefetch[key] = (params, pcfg, losses, pdata)
        return self._prefetch[key]

    def outputs(self, ds: int, use_prefetch: bool = True):
        from repro.core.recmg import precompute_outputs

        key = (ds, use_prefetch)
        if key not in self._outputs:
            cparams, mcfg, _ = self.caching_model(ds)
            pf = None
            if use_prefetch:
                pparams, pcfg, _, _ = self.prefetch_model(ds)
                pf = (pparams, pcfg)
            self._outputs[key] = precompute_outputs(
                self.trace(ds), caching=(cparams, mcfg), prefetch=pf)
        return self._outputs[key]

    # ---------------- reporting ----------------
    def emit(self, bench: str, name: str, value, derived: str = ""):
        row = {"bench": bench, "name": name, "value": value,
               "derived": derived}
        self.rows.append(row)
        if isinstance(value, float):
            value = round(value, 6)
        print(f"{bench},{name},{value},{derived}", flush=True)

    def emit_percentiles(self, bench: str, prefix: str, res: dict,
                         derived: str = ""):
        """Emit the p50/p95/p99 per-batch latency fields a ``serve_trace``
        result carries, so the bench trajectory tracks tail latency
        alongside means."""
        for q in ("p50", "p95", "p99"):
            self.emit(bench, f"{prefix}_{q}_batch_ms",
                      round(res[f"{q}_batch_ms"], 3),
                      derived or f"measured per-batch wall {q}")

    def emit_snapshot(self, bench: str, name: str, snap: dict,
                      derived: str = ""):
        """Store a metrics-registry snapshot (the ``metrics`` entry of a
        ``serve_trace`` / ``replay_scenario`` result) as one artifact row
        — full flat counter space in ``bench_results.json``, a one-line
        summary on stdout — after asserting its accounting identities
        reconcile (the bench is a reconciliation surface too)."""
        from repro.obs import MetricsRegistry, reconcile

        reconcile(metrics=snap, strict=True)
        flat = {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in MetricsRegistry.from_snapshot(snap)
                .as_dict().items()}
        self.rows.append({"bench": bench, "name": f"{name}_metrics",
                          "value": flat,
                          "derived": derived or "metrics-registry snapshot "
                          "(reconciled)"})
        print(f"{bench},{name}_metrics,<{len(flat)} metrics: "
              f"reconciled>,{derived}", flush=True)


def geomean(xs) -> float:
    xs = np.asarray([max(x, 1e-12) for x in xs], dtype=np.float64)
    return float(np.exp(np.log(xs).mean()))
