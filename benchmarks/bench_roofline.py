"""Roofline summary from the dry-run artifacts + kernel micro-bench.

The roofline table itself is produced by ``repro.launch.roofline`` from the
compiled dry-run; this bench re-emits the headline numbers into the CSV
stream and micro-times the XLA reference paths of the Pallas kernels (the
kernels run only on TPU; interpret-mode timing is meaningless)."""
from __future__ import annotations

import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import BenchContext


def roofline_summary(ctx: BenchContext, tag: str = "baseline"):
    from repro.launch.roofline import load_rows

    d = Path("runs/dryrun") / tag
    if not d.exists():
        ctx.emit("roofline", "missing", 0,
                 f"run `python -m repro.launch.dryrun --all` first ({d})")
        return
    rows = load_rows(d, "16x16")
    if not rows:
        return
    for r in sorted(rows, key=lambda r: -r["roofline_fraction"])[:5]:
        ctx.emit("roofline", f"best_{r['arch']}__{r['shape']}",
                 round(r["roofline_fraction"], 4),
                 f"dominant={r['dominant']}")
    fracs = [r["roofline_fraction"] for r in rows]
    ctx.emit("roofline", "cells", len(rows))
    ctx.emit("roofline", "median_fraction", round(float(np.median(fracs)), 4))
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    for k, v in dom.items():
        ctx.emit("roofline", f"bound_by_{k}", v)


def _time_us(fn, *args, iters=20):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_microbench(ctx: BenchContext):
    from repro.kernels import ops

    table = jax.random.normal(jax.random.PRNGKey(0), (20_000, 128))
    idx = jax.random.randint(jax.random.PRNGKey(1), (512, 20), 0, 20_000)
    us = _time_us(lambda: ops.gather_pool(table, idx))
    ctx.emit("kernels", "gather_pool_512x20_us", round(us, 1),
             "XLA ref path (Pallas path is TPU-only)")

    po = jax.random.normal(jax.random.PRNGKey(0), (4096, 5, 25))
    w = jax.random.normal(jax.random.PRNGKey(1), (4096, 15, 25))
    us = _time_us(lambda: ops.chamfer(po, w))
    ctx.emit("kernels", "chamfer_4096_us", round(us, 1))

    q = jax.random.normal(jax.random.PRNGKey(0), (8, 512, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 512, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (8, 512, 64))
    us = _time_us(lambda: ops.flash_attention(q, k, v))
    ctx.emit("kernels", "attention_8x512_us", round(us, 1))


def run(ctx: BenchContext):
    roofline_summary(ctx)
    kernel_microbench(ctx)
