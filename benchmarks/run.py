"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only caching,...]

Prints ``bench,name,value,derived`` CSV rows and writes
runs/bench_results.json.  Mapping to the paper:

    bench_caching   -> Table I, Fig 3, Fig 8, Fig 13, Fig 15
    bench_prefetch  -> Fig 9, Fig 10, Table II, Fig 11, Fig 12, Fig 14,
                       Table IV
    bench_e2e       -> Fig 16, Fig 17, Fig 18, Fig 19
    bench_roofline  -> assignment §Roofline + kernel micro-bench
"""
from __future__ import annotations

import argparse
import json
import re
import time
from pathlib import Path

from benchmarks.common import BenchConfig, BenchContext


def write_trajectory_artifact(rows, args, out_dir: Path = Path("runs")):
    """Write the per-PR ``BENCH_<n>.json`` trajectory artifact: the full
    row list plus the invocation knobs, numbered one past the highest
    ``BENCH_*.json`` already present (so a repo's run history reads as a
    perf trajectory — ROADMAP item 5's first-class perf history).
    Returns the path written."""
    out_dir.mkdir(exist_ok=True)
    pat = re.compile(r"^BENCH_(\d+)\.json$")
    taken = [int(m.group(1)) for p in out_dir.glob("BENCH_*.json")
             if (m := pat.match(p.name))]
    n = max(taken, default=0) + 1
    path = out_dir / f"BENCH_{n}.json"
    path.write_text(json.dumps(
        {"n": n, "args": vars(args), "rows": rows}, indent=2))
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: caching,prefetch,e2e,roofline")
    ap.add_argument("--accesses", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = BenchConfig(quick=args.quick)
    if args.accesses:
        cfg.n_accesses = args.accesses
    if args.epochs:
        cfg.epochs = args.epochs
    ctx = BenchContext(cfg)
    print("bench,name,value,derived")

    mods = {
        "caching": "benchmarks.bench_caching",
        "prefetch": "benchmarks.bench_prefetch",
        "e2e": "benchmarks.bench_e2e",
        "roofline": "benchmarks.bench_roofline",
    }
    only = [s for s in args.only.split(",") if s] or list(mods)
    import importlib

    for name in only:
        t0 = time.time()
        mod = importlib.import_module(mods[name])
        mod.run(ctx)
        ctx.emit("meta", f"{name}_wall_s", round(time.time() - t0, 1))

    Path("runs").mkdir(exist_ok=True)
    Path("runs/bench_results.json").write_text(json.dumps(ctx.rows, indent=2))
    print(f"# wrote runs/bench_results.json ({len(ctx.rows)} rows)")
    traj = write_trajectory_artifact(ctx.rows, args)
    print(f"# wrote {traj} (trajectory artifact)")


if __name__ == "__main__":
    main()
