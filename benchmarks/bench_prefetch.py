"""Paper Figs. 9/10 (prefetch correctness/coverage), Table II (prediction
cost), Fig. 11 (Chamfer vs L2 ablation), Fig. 12 (window sensitivity),
Fig. 14 (access breakdown), Table IV (prefetcher statistics)."""
from __future__ import annotations

import time
from collections import Counter

import numpy as np

from benchmarks.common import BenchContext, geomean
from repro.core.cache_sim import FALRU, make_cache, simulate
from repro.core.prefetch_model import (decode_to_ids, predict_sequences,
                                       sequence_metrics)
from repro.core.prefetchers import make_prefetcher, prediction_metrics
from repro.core.recmg import run_lru_pf, run_recmg


def _recmg_sequence_metrics(ctx, ds: int, window: int = 15,
                            loss: str = "chamfer", backbone: str = "lstm"):
    tr = ctx.trace(ds)
    pparams, pcfg, losses, pdata = ctx.prefetch_model(ds, loss=loss,
                                                      window=window,
                                                      backbone=backbone)
    n_ev = max(1, len(pdata) // 5)
    ev_idx = np.arange(len(pdata) - n_ev, len(pdata))
    from repro.core.prefetch_model import PrefetchData

    pev = PrefetchData(pdata.base.batch(ev_idx),
                       {k: v[ev_idx] for k, v in pdata.w_feats.items()})
    po = predict_sequences(pparams, pcfg, pev)
    freq = Counter(tr.global_id[: int(len(tr) * 0.8)].tolist())
    cand = np.array(sorted(k for k, _ in freq.most_common(2000)))
    ids = decode_to_ids(pparams, pcfg, po, cand, tr)
    gt = np.round(pev.w_feats["wn"] * tr.n_vectors).astype(np.int64)
    return sequence_metrics(ids, gt[:, :window]), losses


def voyager_scaling(ctx: BenchContext):
    """The paper's Voyager finding: one-hot labeling over millions of
    vectors is infeasible (OOM on 512GB DDR) — quantified, plus the small-
    scale accuracy it achieves where it *does* fit."""

    from repro.core.features import make_windows
    from repro.core.voyager import (VoyagerConfig, label_memory_bytes,
                                    predict_next, train_voyager)

    paper_scale = VoyagerConfig(n_vectors=62_000_000)
    ctx.emit("voyager", "label_bytes_paper_scale",
             float(label_memory_bytes(paper_scale, 400_000_000)),
             "one-hot labels for 62M vectors x 400M samples -> OOM (paper)")
    tr = ctx.trace(0)
    vcfg = VoyagerConfig(n_vectors=tr.n_vectors, page_size=256)
    ctx.emit("voyager", "head_params_here",
             vcfg.hidden * (vcfg.n_pages + vcfg.page_size),
             f"{vcfg.n_pages} pages at bench scale")
    data = make_windows(tr, stride=10)
    n = int(len(data) * 0.8)
    vp, losses = train_voyager(data.batch(np.arange(n)), vcfg, tr.n_tables,
                               epochs=max(2, ctx.cfg.epochs // 2))
    pred = predict_next(vp, vcfg, data.batch(np.arange(n, len(data))))
    gtw = np.round(data.y_window[n:] * tr.n_vectors).astype(np.int64)
    inw = float(np.mean([p in set(w) for p, w in zip(pred, gtw)]))
    ctx.emit("voyager", "in_window_correctness", round(inw, 4),
             "next-id classifier, within 15-access window")


def fig9_10_prefetch_quality(ctx: BenchContext):
    for ds in range(min(3, ctx.cfg.n_datasets)):
        tr = ctx.trace(ds)
        keys = tr.global_id[:60_000]
        for name in ("bingo", "domino", "bop"):
            m = prediction_metrics(keys, make_prefetcher(name), window=15)
            ctx.emit("fig9", f"ds{ds}_{name}_correctness",
                     round(m["correctness"], 4))
            ctx.emit("fig10", f"ds{ds}_{name}_coverage",
                     round(m["coverage"], 4))
        m, _ = _recmg_sequence_metrics(ctx, ds)
        ctx.emit("fig9", f"ds{ds}_recmg_correctness",
                 round(m["correctness"], 4), "paper: ~0.37")
        ctx.emit("fig10", f"ds{ds}_recmg_coverage", round(m["coverage"], 4))
        mt, _ = _recmg_sequence_metrics(ctx, ds, backbone="transformer")
        ctx.emit("fig9", f"ds{ds}_transfetch_correctness",
                 round(mt["correctness"], 4), "transformer backbone")
        ctx.emit("fig10", f"ds{ds}_transfetch_coverage",
                 round(mt["coverage"], 4))


def table2_prediction_cost(ctx: BenchContext):
    tr = ctx.trace(0)
    keys = tr.global_id[:20_000]
    for name in ("bingo", "domino", "bop"):
        pf = make_prefetcher(name)
        t0 = time.perf_counter()
        for k in keys:
            pf.on_access(int(k), True)
        us = (time.perf_counter() - t0) / len(keys) * 1e6
        ctx.emit("table2", f"{name}_us_per_prediction", round(us, 2))
    # RecMG: batched CPU inference cost per predicted chunk.
    pparams, pcfg, _, pdata = ctx.prefetch_model(0)
    from repro.core.prefetch_model import PrefetchData

    sub = PrefetchData(pdata.base.batch(np.arange(512)),
                       {k: v[:512] for k, v in pdata.w_feats.items()})
    predict_sequences(pparams, pcfg, sub)  # warm the jit
    t0 = time.perf_counter()
    for _ in range(3):
        predict_sequences(pparams, pcfg, sub)
    us = (time.perf_counter() - t0) / (3 * 512) * 1e6
    ctx.emit("table2", "recmg_us_per_prediction", round(us, 2),
             "batched chunk inference, paper: 92us")
    tparams, tcfg, _, _ = ctx.prefetch_model(0, backbone="transformer")
    sub2 = PrefetchData(pdata.base.batch(np.arange(512)),
                        {k: v[:512] for k, v in pdata.w_feats.items()})
    predict_sequences(tparams, tcfg, sub2)
    t0 = time.perf_counter()
    for _ in range(3):
        predict_sequences(tparams, tcfg, sub2)
    tus = (time.perf_counter() - t0) / (3 * 512) * 1e6
    ctx.emit("table2", "transfetch_us_per_prediction", round(tus, 2),
             f"paper: TransFetch 10.6x RecMG; here {tus/max(us,1e-9):.1f}x")


def fig11_loss_ablation(ctx: BenchContext):
    """Chamfer + decoupled window vs L2 with window == |PO|."""
    for loss in ("chamfer", "l2"):
        window = 15 if loss == "chamfer" else 5
        _, losses = _recmg_sequence_metrics(ctx, 0, window=window, loss=loss)
        l0 = float(np.mean(losses[:10]))
        l1 = float(np.mean(losses[-10:]))
        ctx.emit("fig11", f"{loss}_loss_start", round(l0, 4))
        ctx.emit("fig11", f"{loss}_loss_end", round(l1, 4),
                 f"rel_drop={1 - l1 / max(l0, 1e-9):.3f}")


def fig12_window_sensitivity(ctx: BenchContext):
    for mult in (1, 2, 3, 4):
        window = 5 * mult
        m, _ = _recmg_sequence_metrics(ctx, 0, window=window)
        ctx.emit("fig12", f"window_{mult}x_correctness",
                 round(m["correctness"], 4),
                 "paper: saturates at 3x |PO|")


def fig14_breakdown(ctx: BenchContext):
    """Access breakdown (cache hit / prefetch hit / on-demand) for Domino,
    Bingo, BOP+LRU, LRU+PF, RecMG."""
    for ds in range(min(3, ctx.cfg.n_datasets)):
        tr = ctx.trace(ds)
        keys = tr.global_id
        cap = ctx.capacity(ds)
        rows = {}
        for name in ("domino", "bingo", "bop"):
            r = simulate(keys, FALRU(cap), make_prefetcher(name))
            rows[name] = r
        outputs = ctx.outputs(ds, use_prefetch=True)
        rows["lru+pf"] = run_lru_pf(tr, cap, outputs)
        rows["recmg"] = run_recmg(tr, cap, outputs)
        for name, r in rows.items():
            ctx.emit("fig14", f"ds{ds}_{name}_cache_hits", int(r.cache_hits))
            ctx.emit("fig14", f"ds{ds}_{name}_prefetch_hits",
                     int(r.prefetch_hits))
            ctx.emit("fig14", f"ds{ds}_{name}_on_demand", int(r.on_demand),
                     f"hit_rate={r.hit_rate:.3f}")
        base = rows["recmg"].on_demand
        for name in ("domino", "bingo", "lru+pf"):
            ctx.emit("fig14", f"ds{ds}_on_demand_reduction_vs_{name}",
                     round(rows[name].on_demand / max(base, 1), 2),
                     "paper: 2.2-4.8x")


def table4_prefetcher_stats(ctx: BenchContext):
    n_ds = min(3, ctx.cfg.n_datasets)
    acc = {}
    issued = {}
    for ds in range(n_ds):
        tr = ctx.trace(ds)
        keys = tr.global_id
        cap = ctx.capacity(ds, 0.15)
        for name in ("bop", "berti", "mab"):
            r = simulate(keys, make_cache("lru_32w", cap),
                         make_prefetcher(name))
            acc.setdefault(f"{name}+lru", []).append(r.prefetch_accuracy)
            issued.setdefault(f"{name}+lru", []).append(r.prefetch_issued)
        outputs = ctx.outputs(ds, use_prefetch=True)
        r = run_recmg(tr, cap, outputs)
        acc.setdefault("recmg", []).append(r.prefetch_accuracy)
        issued.setdefault("recmg", []).append(r.prefetch_issued)
        r = run_lru_pf(tr, cap, outputs)
        acc.setdefault("pm+lru", []).append(r.prefetch_accuracy)
        issued.setdefault("pm+lru", []).append(r.prefetch_issued)
    for name in acc:
        ctx.emit("table4", f"{name}_prefetch_accuracy",
                 round(geomean(acc[name]), 4))
        ctx.emit("table4", f"{name}_issued",
                 int(np.mean(issued[name])))


def run(ctx: BenchContext):
    fig9_10_prefetch_quality(ctx)
    voyager_scaling(ctx)
    table2_prediction_cost(ctx)
    fig11_loss_ablation(ctx)
    fig12_window_sensitivity(ctx)
    fig14_breakdown(ctx)
    table4_prefetcher_stats(ctx)
